//! Observability invariants (ISSUE 9): the trace sink is a pure
//! observer — every simulator output is bit-for-bit identical with a
//! recording sink attached, across the plain, shared-costs-memoized,
//! cluster, and autoscale entry points — and the Chrome-trace export is
//! schema-complete (every event carries `ph`/`ts`/`pid`/`tid`, request
//! spans nest, and request ids are conserved against the completion
//! list).

use llm_perf_lab::config::{Arrival, LlamaConfig, SloSpec, TenantMix, WorkloadSpec};
use llm_perf_lab::hw::{Platform, PlatformId};
use llm_perf_lab::search::{autotune_serve_exec, ExecPolicy, ReplicaSpace, SearchBudget};
use llm_perf_lab::serve::{
    simulate_autoscale, simulate_autoscale_traced, simulate_cluster, simulate_cluster_shared,
    simulate_cluster_shared_traced, simulate_cluster_traced, simulate_requests_on,
    simulate_requests_on_traced, simulate_requests_shared, simulate_requests_shared_traced,
    AutoscalePolicy, AutoscaleResult, AutoscaleSpec, Balancer, ClusterSpec, EngineSpec,
    SharedCosts, SimResult,
};
use llm_perf_lab::trace::{chrome_trace, MetricsRegistry, TraceBuffer, TraceEvent};
use llm_perf_lab::util::json::Json;

fn lab() -> (Platform, LlamaConfig, EngineSpec) {
    (Platform::get(PlatformId::A800), LlamaConfig::llama2_7b(), EngineSpec::vllm())
}

/// A bursty stream dense enough to exercise queueing, batching, and
/// (at cluster scale) retry dispatch.
fn workload(n: u64) -> WorkloadSpec {
    WorkloadSpec::new(n).arrival(Arrival::Bursty { qps: 14.0, on_s: 2.0, off_s: 3.0 }).seed(7)
}

/// Bit-for-bit equality — `to_bits`, not epsilon: the determinism
/// contract says tracing must not perturb a single ULP.
fn assert_bitwise_eq(a: &SimResult, b: &SimResult) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "makespan");
    assert_eq!(a.output_tokens, b.output_tokens);
    assert_eq!(a.generated_tokens, b.generated_tokens);
    assert_eq!(a.decode_iters, b.decode_iters);
    assert_eq!(a.prefill_iters, b.prefill_iters);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.rejected, b.rejected);
    assert_eq!(a.mean_iter_time.to_bits(), b.mean_iter_time.to_bits(), "mean_iter_time");
    assert_eq!(a.peak_kv_util.to_bits(), b.peak_kv_util.to_bits(), "peak_kv_util");
    assert_eq!(a.mean_batch.to_bits(), b.mean_batch.to_bits(), "mean_batch");
    assert_eq!(a.peak_batch, b.peak_batch);
    assert_eq!(a.completions.len(), b.completions.len());
    for (x, y) in a.completions.iter().zip(&b.completions) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "finish of {}", x.id);
        assert_eq!(x.latency.to_bits(), y.latency.to_bits(), "latency of {}", x.id);
        assert_eq!(x.ttft.to_bits(), y.ttft.to_bits(), "ttft of {}", x.id);
        assert_eq!(x.output_tokens, y.output_tokens);
    }
}

fn assert_autoscale_eq(a: &AutoscaleResult, b: &AutoscaleResult) {
    assert_bitwise_eq(&a.cluster.merged, &b.cluster.merged);
    assert_eq!(a.offered, b.offered);
    assert_eq!(a.shed, b.shed);
    assert_eq!(a.cold_starts, b.cold_starts);
    assert_eq!(a.gpu_hours.to_bits(), b.gpu_hours.to_bits(), "gpu_hours");
    assert_eq!(a.static_gpu_hours.to_bits(), b.static_gpu_hours.to_bits());
    assert_eq!(a.cold_start_gpu_hours.to_bits(), b.cold_start_gpu_hours.to_bits());
    assert_eq!(a.overall_attainment.to_bits(), b.overall_attainment.to_bits(), "attainment");
    assert_eq!(a.samples.len(), b.samples.len());
    assert_eq!(a.events.len(), b.events.len());
    assert_eq!(a.lives.len(), b.lives.len());
    assert_eq!(a.tenants.len(), b.tenants.len());
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!((x.offered, x.shed, x.rejected, x.completed),
                   (y.offered, y.shed, y.rejected, y.completed), "tenant {}", x.name);
    }
}

#[test]
fn tracing_is_a_pure_observer_on_single_deployment() {
    let (plat, cfg, engine) = lab();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let reqs = workload(120).generate().unwrap();
    let plain = simulate_requests_on(&plat, &cfg, &engine, &plan, &reqs);
    let mut buf = TraceBuffer::new();
    let traced = simulate_requests_on_traced(&plat, &cfg, &engine, &plan, &reqs, &mut buf);
    assert_bitwise_eq(&plain, &traced);
    assert!(!buf.is_empty(), "an active sink must record the replay");
    let completed = buf
        .events()
        .iter()
        .filter(|(_, e)| matches!(e, TraceEvent::Completed { .. }))
        .count();
    assert_eq!(completed, traced.completions.len(), "one Completed event per completion");
}

#[test]
fn tracing_is_a_pure_observer_on_shared_costs_path() {
    let (plat, cfg, engine) = lab();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let reqs = workload(100).generate().unwrap();
    // fresh memo each side: the traced run must not change what gets
    // memoized, only observe it
    let plain = simulate_requests_shared(&plat, &cfg, &engine, &plan, &reqs, &SharedCosts::new());
    let mut buf = TraceBuffer::new();
    let traced = simulate_requests_shared_traced(&plat, &cfg, &engine, &plan, &reqs,
                                                 &SharedCosts::new(), &mut buf);
    assert_bitwise_eq(&plain, &traced);
    // and both agree with the unmemoized event loop
    let direct = simulate_requests_on(&plat, &cfg, &engine, &plan, &reqs);
    assert_bitwise_eq(&direct, &traced);
}

#[test]
fn tracing_is_a_pure_observer_on_clusters() {
    let (plat, cfg, engine) = lab();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let cluster = ClusterSpec::new(3, plan, Balancer::JoinShortestQueue).seed(7);
    let reqs = workload(150).generate().unwrap();
    let plain = simulate_cluster(&plat, &cfg, &engine, &cluster, &reqs);
    let mut buf = TraceBuffer::new();
    let traced = simulate_cluster_traced(&plat, &cfg, &engine, &cluster, &reqs, &mut buf);
    assert_bitwise_eq(&plain.merged, &traced.merged);
    for (x, y) in plain.replicas.iter().zip(&traced.replicas) {
        assert_eq!(x.requests, y.requests, "replica {}", x.replica);
        assert_eq!(x.completions, y.completions);
        assert_eq!(x.output_tokens, y.output_tokens);
        assert_eq!(x.makespan.to_bits(), y.makespan.to_bits());
        assert_eq!(x.decode_iters, y.decode_iters);
    }
    let mut buf2 = TraceBuffer::new();
    let shared = simulate_cluster_shared_traced(&plat, &cfg, &engine, &cluster, &reqs,
                                                &SharedCosts::new(), &mut buf2);
    assert_bitwise_eq(&plain.merged, &shared.merged);
    let plain_shared =
        simulate_cluster_shared(&plat, &cfg, &engine, &cluster, &reqs, &SharedCosts::new());
    assert_bitwise_eq(&plain_shared.merged, &shared.merged);
    // every dispatch decision was observed, one per offered request
    let dispatched = buf
        .events()
        .iter()
        .filter(|(_, e)| matches!(e, TraceEvent::Dispatched { .. }))
        .count();
    assert_eq!(dispatched as u64, reqs.len() as u64);
}

/// The acceptance scenario: fixed-seed diurnal traffic, two tenant
/// classes, an autoscaling fleet — results bit-identical with tracing,
/// and the exported Chrome trace carries one process lane per replica
/// slot and at least one `req` span per completed request.
#[test]
fn autoscale_trace_is_bit_identical_and_exports_lanes_and_spans() {
    let (plat, cfg, engine) = lab();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let reqs = WorkloadSpec::new(200)
        .arrival(Arrival::Diurnal { base_qps: 2.0, peak_qps: 8.0, period_s: 60.0 })
        .seed(42)
        .generate()
        .unwrap();
    let spec = AutoscaleSpec {
        plan,
        balancer: Balancer::JoinShortestQueue,
        policy: AutoscalePolicy::new(1, 3).interval(10.0).cold_start(10.0).drain(15.0),
        tenants: TenantMix::two_class(),
        seed: 42,
    };
    let plain = simulate_autoscale(&plat, &cfg, &engine, &spec, &reqs);
    let mut buf = TraceBuffer::new();
    let traced = simulate_autoscale_traced(&plat, &cfg, &engine, &spec, &reqs, &mut buf);
    assert_autoscale_eq(&plain, &traced);

    let doc = Json::parse(&chrome_trace(buf.events()).render()).unwrap();
    let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
    assert!(!events.is_empty());
    let mut pids = std::collections::BTreeSet::new();
    for ev in events {
        // schema completeness: every record is Perfetto-ingestible
        assert!(ev.get("ph").and_then(Json::as_str).is_some(), "missing ph");
        assert!(ev.get("ts").and_then(Json::as_f64).is_some(), "missing ts");
        assert!(ev.get("pid").and_then(Json::as_u64).is_some(), "missing pid");
        assert!(ev.get("tid").and_then(Json::as_u64).is_some(), "missing tid");
        pids.insert(ev.get("pid").and_then(Json::as_u64).unwrap());
    }
    assert_eq!(pids.len(), traced.lives.len(), "one process lane per replica slot");
    let req_spans = events
        .iter()
        .filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some("X")
                && e.get("name").and_then(Json::as_str).is_some_and(|n| n.starts_with("req "))
        })
        .count();
    assert_eq!(req_spans, traced.cluster.merged.completions.len(),
               ">= 1 lifecycle span per completed request, ids conserved");

    // the metrics registry books balance against the same run
    let m = MetricsRegistry::from_events(buf.events());
    assert_eq!(m.counter_value("completions"),
               traced.cluster.merged.completions.len() as u64);
    assert_eq!(m.counter_value("shed"), traced.shed);
    let metrics = Json::parse(&m.to_json().render()).unwrap();
    assert_eq!(metrics.get("schema").and_then(Json::as_str), Some("llmperf-metrics/v1"));
    let gauges = metrics.get("gauges").and_then(Json::as_arr).unwrap();
    let tenant_series = gauges
        .iter()
        .filter_map(|g| g.get("name").and_then(Json::as_str))
        .filter(|n| n.starts_with("goodput_tokens{tenant="))
        .count();
    assert_eq!(tenant_series, 2, "one goodput series per tenant class");
}

/// The staged and exhaustive autotuner pipelines fill the funnel
/// counters consistently, and instrumentation never perturbs the
/// frontier: two identical searches agree bit-for-bit.
#[test]
fn search_funnel_counters_are_consistent_and_frontier_stable() {
    let (plat, cfg, _) = lab();
    let base = WorkloadSpec::at_once(40, 256, 16);
    let slo = SloSpec::new(0.9, 6.0, f64::MAX);
    let run = |staged: bool| {
        autotune_serve_exec(&plat, &cfg, &EngineSpec::all(), &base, &slo, None, (0.5, 8.0),
                            ReplicaSpace::default(), SearchBudget::default(),
                            ExecPolicy { jobs: 2, staged })
            .unwrap()
    };
    let a = run(false);
    let b = run(false);
    assert_eq!(a.frontier.len(), b.frontier.len());
    for (x, y) in a.frontier_evals().iter().zip(b.frontier_evals().iter()) {
        assert_eq!(x.gpus, y.gpus);
        assert_eq!(x.cost_per_hour.to_bits(), y.cost_per_hour.to_bits());
        assert_eq!(x.max_qps.map(f64::to_bits), y.max_qps.map(f64::to_bits));
    }
    // exhaustive: everything costed goes through the full stage
    assert_eq!(a.stats.stage_full, a.stats.costed);
    assert!(a.stats.wall_s > 0.0, "wall-clock must be recorded");
    let s = run(true);
    // every stage-C entrant is fully evaluated, staged or bypassed
    assert_eq!(s.stats.stage_full, s.stats.costed);
    if s.stats.stage_screened > 0 {
        // staged: the funnel narrows monotonically
        assert!(s.stats.stage_quarter <= s.stats.stage_screened);
        assert!(s.stats.stage_wall_s.iter().all(|&w| w >= 0.0));
    }
    assert!(s.stats.wall_s > 0.0);
}
