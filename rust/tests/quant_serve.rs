//! Equivalence/invariant harness for the quantized-serving + speculative-
//! decoding axes (ISSUE 8): explicit fp16/fp16/no-spec engines are
//! bit-for-bit the stock engines across every simulation path, disabled
//! spec-decode spellings are bit-for-bit vanilla, KV quantization grows
//! capacity without ever shrinking SLO capacity, precision variants never
//! collide in the shared cost tables, and on a 24 GB card the widened
//! autotune-serve space finds a quantized deployment that meets the SLO
//! with strictly fewer GPUs than the best fp16 point.

use llm_perf_lab::config::{Arrival, LlamaConfig, SloSpec, WorkloadSpec};
use llm_perf_lab::hw::{Platform, PlatformId};
use llm_perf_lab::report::load::max_qps_under_slo_on;
use llm_perf_lab::search::{autotune_serve, expand_engine_variants, ReplicaSpace, SearchBudget};
use llm_perf_lab::serve::{
    simulate_cluster, simulate_requests, simulate_requests_on, simulate_requests_shared, Balancer,
    ClusterSpec, EngineSpec, KvPrecision, SharedCosts, SimResult, SpecDecode, WeightPrecision,
};

/// Bit-level signature of a simulation: makespan, iteration counts, and
/// every completion's (id, ttft, latency) down to the f64 bit pattern.
fn sim_sig(r: &SimResult) -> (u64, u64, u64, u64, Vec<(u64, u64, u64)>) {
    (
        r.makespan.to_bits(),
        r.decode_iters,
        r.prefill_iters,
        r.preemptions,
        r.completions.iter().map(|c| (c.id, c.ttft.to_bits(), c.latency.to_bits())).collect(),
    )
}

/// Tentpole equivalence: an engine explicitly configured to fp16 weights,
/// fp16 KV, and no speculative decoding is bit-for-bit the stock engine —
/// same variant name, same plan, same event-loop trajectory — for every
/// modeled engine.
#[test]
fn explicit_fp16_no_spec_is_bit_identical_to_stock_engines() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let reqs = WorkloadSpec::new(40).seed(7).generate().unwrap();
    for stock in EngineSpec::all() {
        let explicit = stock
            .clone()
            .with_weight_precision(WeightPrecision::Fp16)
            .with_kv_precision(KvPrecision::Fp16)
            .with_spec_decode(SpecDecode::off());
        assert_eq!(explicit.variant_name(), stock.name, "fp16 defaults must not rename");
        let sp = stock.plan(&plat, &cfg).unwrap();
        let ep = explicit.plan(&plat, &cfg).unwrap();
        assert_eq!(sp.kv_capacity_tokens, ep.kv_capacity_tokens, "{}", stock.name);
        assert_eq!(sp.tp(), ep.tp(), "{}", stock.name);
        let a = simulate_requests(&plat, &cfg, &stock, &reqs).unwrap();
        let b = simulate_requests(&plat, &cfg, &explicit, &reqs).unwrap();
        assert_eq!(sim_sig(&a), sim_sig(&b), "{}", stock.name);
    }
}

/// Both "off" spellings of speculative decoding — zero acceptance and a
/// lookahead of one — replay bit-for-bit as the vanilla engine through
/// the single-box event loop and the replica-cluster path.
#[test]
fn disabled_spec_spellings_match_vanilla_across_sim_and_cluster() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let engine = EngineSpec::vllm();
    let reqs = WorkloadSpec::new(48)
        .seed(11)
        .arrival(Arrival::Poisson { qps: 4.0 })
        .generate()
        .unwrap();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let vanilla = simulate_requests_on(&plat, &cfg, &engine, &plan, &reqs);
    let cluster = ClusterSpec::new(2, plan, Balancer::RoundRobin);
    let cvanilla = simulate_cluster(&plat, &cfg, &engine, &cluster, &reqs);
    for spelled in [
        SpecDecode { accept_rate: 0.0, lookahead: 8 },
        SpecDecode { accept_rate: 0.6, lookahead: 1 },
    ] {
        assert!(!spelled.enabled());
        let off = engine.clone().with_spec_decode(spelled);
        let r = simulate_requests_on(&plat, &cfg, &off, &plan, &reqs);
        assert_eq!(sim_sig(&vanilla), sim_sig(&r), "{spelled:?}");
        let cr = simulate_cluster(&plat, &cfg, &off, &cluster, &reqs);
        assert_eq!(sim_sig(&cvanilla.merged), sim_sig(&cr.merged), "{spelled:?}");
    }
}

/// The shared cost tables key on precision: an fp16 run through a shared
/// table is bit-identical to the unshared path, quantized variants with
/// the same parallel shape pull strictly faster (not colliding) entries,
/// and replaying fp16 through the now-populated table is still identical.
#[test]
fn shared_cost_tables_keep_precision_variants_distinct() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let engine = EngineSpec::vllm();
    let reqs = WorkloadSpec::new(40).seed(7).generate().unwrap();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let costs = SharedCosts::new();
    let unshared = simulate_requests_on(&plat, &cfg, &engine, &plan, &reqs);
    let shared = simulate_requests_shared(&plat, &cfg, &engine, &plan, &reqs, &costs);
    assert_eq!(sim_sig(&unshared), sim_sig(&shared));
    // same parallel shape + same KV capacity, different precision key:
    // a collision would hand the quantized run fp16 costs (or vice versa)
    let mut p8 = plan;
    p8.kv_precision = KvPrecision::Int8;
    let kv8 = engine.clone().with_kv_precision(KvPrecision::Int8);
    let r8 = simulate_requests_shared(&plat, &cfg, &kv8, &p8, &reqs, &costs);
    assert!(r8.makespan < shared.makespan, "INT8 KV must shrink decode reads");
    let mut p4 = plan;
    p4.weight_precision = WeightPrecision::Int4;
    let w4 = engine.clone().with_weight_precision(WeightPrecision::Int4);
    let r4 = simulate_requests_shared(&plat, &cfg, &w4, &p4, &reqs, &costs);
    assert!(r4.makespan < shared.makespan, "INT4 weights must shrink GEMM reads");
    let replay = simulate_requests_shared(&plat, &cfg, &engine, &plan, &reqs, &costs);
    assert_eq!(sim_sig(&shared), sim_sig(&replay), "fp16 entries survived unclobbered");
}

/// KV quantization grows the admissible batch (KV pool tokens) strictly
/// and monotonically with precision, and never shrinks the bisected
/// max-QPS-under-SLO capacity of the same TP degree.
#[test]
fn kv8_grows_max_batch_and_never_shrinks_slo_capacity() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let base = WorkloadSpec::new(40).seed(7);
    let slo = SloSpec::new(0.9, 4.0, 0.25);
    let fp = EngineSpec::vllm();
    let kv8 = fp.clone().with_kv_precision(KvPrecision::Int8);
    let kv4 = fp.clone().with_kv_precision(KvPrecision::Int4);
    let pf = fp.plan_with_tp(&plat, &cfg, 1).unwrap();
    let p8 = kv8.plan_with_tp(&plat, &cfg, 1).unwrap();
    let p4 = kv4.plan_with_tp(&plat, &cfg, 1).unwrap();
    assert!(p8.kv_capacity_tokens > pf.kv_capacity_tokens);
    assert!(p4.kv_capacity_tokens > p8.kv_capacity_tokens);
    let qf = max_qps_under_slo_on(&plat, &cfg, &fp, &pf, &base, &slo, 0.5, 16.0).unwrap();
    let q8 = max_qps_under_slo_on(&plat, &cfg, &kv8, &p8, &base, &slo, 0.5, 16.0).unwrap();
    assert!(qf.is_some(), "7B TP1 on A800 must have some SLO capacity");
    assert!(
        q8.unwrap_or(0.0) >= qf.unwrap_or(0.0),
        "KV8 capacity {q8:?} < fp16 capacity {qf:?}"
    );
}

/// Acceptance-rate speculative decoding is a modeled trade, not a free
/// win: high acceptance beats vanilla on the same plan, and a draft that
/// is almost never accepted pays its overhead and loses.
#[test]
fn spec_decode_speedup_tracks_acceptance_rate_on_a_fixed_plan() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let engine = EngineSpec::vllm();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let reqs = WorkloadSpec::new(40).seed(7).generate().unwrap();
    let vanilla = simulate_requests_on(&plat, &cfg, &engine, &plan, &reqs);
    let good = engine.clone().with_spec_decode(SpecDecode { accept_rate: 0.9, lookahead: 4 });
    let fast = simulate_requests_on(&plat, &cfg, &good, &plan, &reqs);
    assert_eq!(fast.completions.len(), vanilla.completions.len());
    assert!(fast.makespan < vanilla.makespan, "90% acceptance must beat vanilla");
    let bad = engine.clone().with_spec_decode(SpecDecode { accept_rate: 0.1, lookahead: 8 });
    let slow = simulate_requests_on(&plat, &cfg, &bad, &plan, &reqs);
    assert!(slow.makespan > vanilla.makespan, "10% acceptance must pay for its draft");
}

/// ISSUE 8 acceptance: on a 24 GB card where fp16 13B needs TP2, the
/// widened precision space finds a quantized deployment on the frontier
/// that meets the same SLO target with strictly fewer GPUs than the best
/// fp16 point — and the claim replays through the serving event loop.
#[test]
fn quantized_frontier_point_beats_best_fp16_on_a_24gb_card() {
    let plat = Platform::get(PlatformId::Rtx3090Nvl);
    let cfg = LlamaConfig::llama2_13b();
    let base = WorkloadSpec::new(40).seed(7);
    let slo = SloSpec::new(0.9, 10.0, 0.5);
    let target = 0.25;
    let fp16 = autotune_serve(
        &plat,
        &cfg,
        &[EngineSpec::vllm()],
        &base,
        &slo,
        Some(target),
        (0.25, 8.0),
        ReplicaSpace::default(),
        SearchBudget { max_costed: usize::MAX, early_prune: false },
    )
    .unwrap();
    let best_fp16 = fp16.min_gpu_point().expect("fp16 13B must deploy at TP2 on 24 GB");
    assert!(best_fp16.gpus >= 2, "fp16 13B weights cannot fit one 24 GB card");
    let engines = expand_engine_variants(
        &[EngineSpec::vllm()],
        &[WeightPrecision::Fp16, WeightPrecision::Int4],
        &[KvPrecision::Fp16, KvPrecision::Int8],
        &[],
    );
    let wide = autotune_serve(
        &plat,
        &cfg,
        &engines,
        &base,
        &slo,
        Some(target),
        (0.25, 8.0),
        ReplicaSpace::default(),
        SearchBudget { max_costed: usize::MAX, early_prune: false },
    )
    .unwrap();
    let best = wide.min_gpu_point().expect("the widened space must keep a feasible point");
    assert!(
        best.gpus < best_fp16.gpus,
        "quantized best ({} GPUs) must undercut fp16 best ({} GPUs)",
        best.gpus,
        best_fp16.gpus
    );
    let name = best.cand.engine.variant_name();
    assert_ne!(name, "vLLM", "the min-GPU winner must be a quantized variant, got {name}");
    let reqs =
        base.clone().arrival(Arrival::Poisson { qps: target }).generate().unwrap();
    let replay = simulate_requests_on(&plat, &cfg, &best.cand.engine, &best.cand.plan, &reqs);
    assert!(replay.meets_slo(&slo), "{name} misses the SLO it was selected for");
}
