//! Property tests for the `parallel/` subsystem: plan enumeration fills
//! the GPU grid exactly, sharded memory tiles back to the unsharded
//! totals, the 1F1B bubble behaves, and the plan-based Megatron simulator
//! reproduces the pre-refactor Table II behavior.

use llm_perf_lab::config::{LlamaConfig, Method, TrainWorkload};
use llm_perf_lab::hw::{Platform, PlatformId, Topology};
use llm_perf_lab::parallel::{bubble_fraction, state_shards, ParallelPlan, PipelineSchedule,
                             StateShards};
use llm_perf_lab::train::{simulate_megatron_plan, simulate_step, simulate_step_megatron};
use llm_perf_lab::util::rng::Rng;

fn wl(bs: u64) -> TrainWorkload {
    TrainWorkload { seq_len: 350, batch_size: bs }
}

fn a800() -> Platform {
    Platform::get(PlatformId::A800)
}

#[test]
fn every_enumerated_plan_fills_the_world() {
    for id in PlatformId::ALL {
        let plat = Platform::get(id);
        for nodes in [1u32, 2, 4] {
            let topo = Topology::multi_node(&plat, nodes);
            for cfg in LlamaConfig::paper_models() {
                let plans = ParallelPlan::enumerate(&topo, &cfg);
                assert!(!plans.is_empty(), "{id:?} x{nodes} {}", cfg.name);
                for p in &plans {
                    assert_eq!(p.tp * p.pp * p.dp, topo.n_gpus(),
                               "{id:?} x{nodes} {} {p}", cfg.name);
                    assert!(p.validate(&topo, &cfg).is_ok());
                    assert!(p.tp <= topo.gpus_per_node);
                    assert_eq!(cfg.n_heads % p.tp as u64, 0);
                }
            }
        }
    }
}

#[test]
fn sharded_memory_sums_to_unsharded_total_across_grid() {
    // summing each rank's shard over the TP×PP grid (and the optimizer
    // over the full world) recovers the unsharded state exactly
    let mut rng = Rng::new(0x51AB);
    let topo = Topology::multi_node(&a800(), 2);
    for cfg in LlamaConfig::paper_models() {
        let plans = ParallelPlan::enumerate(&topo, &cfg);
        for _ in 0..20 {
            let plan = plans[rng.index(plans.len())];
            let s = state_shards(&cfg, &plan);
            let (w, g, o) = StateShards::unsharded(&cfg);
            let grid = plan.model_shard_degree() as f64;
            let rel = |a: f64, b: f64| (a - b).abs() / b;
            assert!(rel(s.weights * grid, w) < 1e-12, "{} {plan}", cfg.name);
            assert!(rel(s.grads * grid, g) < 1e-12, "{} {plan}", cfg.name);
            assert!(rel(s.optimizer * plan.world() as f64, o) < 1e-12,
                    "{} {plan}", cfg.name);
        }
    }
}

#[test]
fn bubble_zero_without_pipeline_and_shrinks_with_micro_batches() {
    for m in [1u64, 3, 17, 256] {
        assert_eq!(bubble_fraction(1, m), 0.0);
    }
    for pp in [2u32, 4, 8] {
        let mut prev = 1.0f64;
        for m in [1u64, 2, 4, 8, 16, 32, 128, 1024] {
            let b = bubble_fraction(pp, m);
            assert!(b > 0.0 && b < 1.0, "pp={pp} m={m}: {b}");
            assert!(b < prev, "pp={pp} m={m}: bubble must shrink");
            // exact closed form (pp-1)/(m+pp-1)
            let expect = (pp as f64 - 1.0) / (m as f64 + pp as f64 - 1.0);
            assert!((b - expect).abs() < 1e-12);
            prev = b;
        }
    }
    // schedule view agrees
    let plan = ParallelPlan::new(1, 4, 2);
    let s = PipelineSchedule::one_f_one_b(&plan, wl(8));
    assert!((s.bubble_fraction() - 3.0 / 11.0).abs() < 1e-12);
}

#[test]
fn plan_based_megatron_matches_the_tp_entrypoint() {
    // simulate_step_megatron(tp) must be exactly the TP×DP plan view
    let topo = Topology::single_node(&a800());
    let cfg = LlamaConfig::llama2_13b();
    for tp in [1u32, 2, 4, 8] {
        for bs in [1u64, 4, 32] {
            let direct = simulate_step_megatron(&a800(), &cfg, tp, wl(bs));
            let plan = ParallelPlan::new(tp, 1, 8 / tp);
            let via_plan = simulate_megatron_plan(&a800(), &topo, &cfg, &plan, wl(bs));
            assert_eq!(direct.is_oom(), via_plan.is_oom(), "tp{tp} bs{bs}");
            assert!((direct.mem.gpu_total() - via_plan.mem.gpu_total()).abs() <= 1.0);
            if direct.is_oom() {
                continue; // step_time is ∞ on both sides
            }
            assert!((direct.step_time - via_plan.step_time).abs() <= 1e-12,
                    "tp{tp} bs{bs}: {} vs {}", direct.step_time, via_plan.step_time);
            assert!((direct.tokens_per_s - via_plan.tokens_per_s).abs() <= 1e-9);
        }
    }
}

#[test]
fn table2_shape_survives_the_refactor() {
    // the pre-refactor Table II shape checks, through plans:
    // (a) Megatron beats DeepSpeed at BS=1 on A800
    let cfg = LlamaConfig::llama2_7b();
    let meg = simulate_step_megatron(&a800(), &cfg, 1, wl(1));
    let ds = simulate_step(&a800(), &cfg, &Method::naive(), wl(1));
    assert!(meg.tokens_per_s > ds.tokens_per_s,
            "megatron {:.0} !> deepspeed {:.0}", meg.tokens_per_s, ds.tokens_per_s);
    // (b) Megatron's footprint is smaller at BS=1
    assert!(meg.mem.gpu_total() < ds.mem.gpu_total());
    // (c) DeepSpeed wins at its max-batch operating point
    let meg32 = simulate_step_megatron(&a800(), &cfg, 1, wl(32));
    let ds4 = simulate_step(&a800(), &cfg, &Method::naive(), wl(4));
    assert!(ds4.tokens_per_s > meg32.tokens_per_s);
    // (d) TP cuts weights and adds collective traffic
    let cfg13 = LlamaConfig::llama2_13b();
    let tp1 = simulate_step_megatron(&a800(), &cfg13, 1, wl(1));
    let tp8 = simulate_step_megatron(&a800(), &cfg13, 8, wl(1));
    assert!(tp8.mem.weights < 0.2 * tp1.mem.weights);
    assert!(tp8.comm_total > 0.0);
}

#[test]
fn serving_deploy_plans_are_parallel_plans() {
    use llm_perf_lab::serve::EngineSpec;
    let plat = a800();
    let p70 = EngineSpec::vllm().plan(&plat, &LlamaConfig::llama2_70b()).unwrap();
    assert!(p70.tp() >= 2);
    assert_eq!(p70.parallel.world(), p70.tp());
    assert_eq!(p70.parallel.tp, p70.tp());
}
