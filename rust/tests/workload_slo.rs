//! End-to-end tests for the workload-generation + SLO layer: the
//! AtOnce regression against the pre-workload `simulate` path, tail
//! behaviour of open-loop arrivals, fixture-trace replay, and the
//! `sweep-load` capacity search.

use llm_perf_lab::config::{
    Arrival, LengthDist, LlamaConfig, ServeWorkload, SloSpec, Trace, WorkloadSpec,
};
use llm_perf_lab::hw::{Platform, PlatformId};
use llm_perf_lab::report::load::{max_qps_under_slo, qps_grid, sweep_load};
use llm_perf_lab::serve::{simulate, simulate_requests, EngineSpec};

const FIXTURE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/trace_bursty_sample.json");

fn a800_7b() -> (Platform, LlamaConfig) {
    (Platform::get(PlatformId::A800), LlamaConfig::llama2_7b())
}

/// The tentpole regression: an `AtOnce` spec must reproduce the legacy
/// burst simulator bit-for-bit (Fig. 6/7–10 outputs unchanged).
#[test]
fn at_once_reproduces_legacy_simulate_bit_for_bit() {
    let (plat, cfg) = a800_7b();
    for engine in EngineSpec::all() {
        let wl = ServeWorkload { n_requests: 120, input_len: 512, output_len: 64, burst: true };
        let legacy = simulate(&plat, &cfg, &engine, &wl).unwrap();
        let reqs = WorkloadSpec::at_once(120, 512, 64).generate().unwrap();
        let new = simulate_requests(&plat, &cfg, &engine, &reqs).unwrap();
        assert_eq!(legacy.makespan, new.makespan, "{}", engine.name);
        assert_eq!(legacy.output_tokens, new.output_tokens);
        assert_eq!(legacy.decode_iters, new.decode_iters);
        assert_eq!(legacy.prefill_iters, new.prefill_iters);
        assert_eq!(legacy.preemptions, new.preemptions);
        assert_eq!(legacy.completions.len(), new.completions.len());
        for (a, b) in legacy.completions.iter().zip(new.completions.iter()) {
            assert_eq!((a.id, a.finish, a.latency, a.ttft), (b.id, b.finish, b.latency, b.ttft));
        }
    }
}

/// Open-loop Poisson arrivals at moderate load must show much lighter
/// TTFT tails than the same requests dispatched as one burst — the
/// queueing effect the closed benchmark can't see.
#[test]
fn poisson_tails_differ_from_burst() {
    let (plat, cfg) = a800_7b();
    let engine = EngineSpec::vllm();
    let burst = simulate_requests(
        &plat,
        &cfg,
        &engine,
        &WorkloadSpec::at_once(150, 512, 64).generate().unwrap(),
    )
    .unwrap();
    let poisson = simulate_requests(
        &plat,
        &cfg,
        &engine,
        &WorkloadSpec::at_once(150, 512, 64)
            .arrival(Arrival::Poisson { qps: 2.0 })
            .generate()
            .unwrap(),
    )
    .unwrap();
    let (b99, p99) = (burst.ttft_cdf().quantile(0.99), poisson.ttft_cdf().quantile(0.99));
    assert!(
        p99 < b99 / 2.0,
        "poisson p99 TTFT {p99:.2}s should be far below burst {b99:.2}s"
    );
    // open-loop arrivals stretch the makespan past the burst's
    assert!(poisson.makespan > burst.makespan);
}

/// Replaying the checked-in bursty fixture produces plausible tails that
/// differ from the burst: idle gaps stretch the makespan to at least the
/// trace duration, and per-burst queueing keeps TTFT well under the
/// all-at-once extreme.
#[test]
fn fixture_trace_replay_differs_from_burst() {
    let (plat, cfg) = a800_7b();
    let engine = EngineSpec::vllm();
    let trace = Trace::load(FIXTURE).unwrap();
    let n = trace.len() as u64;
    let duration = trace.duration();
    let trace_reqs = WorkloadSpec::from_trace(trace).generate().unwrap();
    let replay = simulate_requests(&plat, &cfg, &engine, &trace_reqs).unwrap();
    assert_eq!(replay.completions.len(), n as usize);
    assert!(replay.makespan >= duration, "idle gaps must advance the clock");
    let burst = simulate_requests(
        &plat,
        &cfg,
        &engine,
        &WorkloadSpec::at_once(n, 512, 128).generate().unwrap(),
    )
    .unwrap();
    let (t99, b99) = (replay.ttft_cdf().quantile(0.99), burst.ttft_cdf().quantile(0.99));
    assert!(t99 < b99, "trace p99 TTFT {t99:.2}s vs burst {b99:.2}s");
    // every TTFT/TPOT is non-negative and bounded by its latency
    for c in &replay.completions {
        assert!(c.ttft >= 0.0 && c.ttft <= c.latency + 1e-9);
        assert!(c.tpot() >= 0.0);
    }
}

/// Fixture round-trip: load → render → parse is the identity.
#[test]
fn fixture_trace_round_trips() {
    let trace = Trace::load(FIXTURE).unwrap();
    assert_eq!(trace.name, "bursty-sample-24");
    assert_eq!(trace.len(), 24);
    let reparsed = Trace::parse(&trace.render()).unwrap();
    assert_eq!(reparsed, trace);
}

/// The capacity search brackets a real knee: the found QPS meets the
/// SLO and 2x the found QPS misses it.  (Arrival streams at different
/// QPS are the same exponential draws rescaled — the probe is
/// deterministic and effectively monotone in offered load.)
#[test]
fn max_qps_search_finds_a_knee() {
    let (plat, cfg) = a800_7b();
    let engine = EngineSpec::vllm();
    let base = WorkloadSpec::new(150).input(LengthDist::Fixed(512)).output(LengthDist::Fixed(64));
    // a strict-but-feasible TTFT budget: trivially met at 0.25 QPS,
    // blown by the near-burst queueing at the top of the bracket
    let slo = SloSpec::new(0.9, 0.5, 0.1);
    let q = max_qps_under_slo(&plat, &cfg, &engine, &base, &slo, 0.25, 256.0)
        .unwrap()
        .expect("0.25 QPS must meet a 0.5s-TTFT SLO");
    assert!(q < 256.0, "the knee must be inside the bracket");
    let at = |qps: f64| {
        simulate_requests(
            &plat,
            &cfg,
            &engine,
            &base.clone().arrival(Arrival::Poisson { qps }).generate().unwrap(),
        )
        .unwrap()
    };
    assert!(at(q).meets_slo(&slo), "found point must meet the SLO");
    assert!(!at(q * 2.0).meets_slo(&slo), "well past the knee must miss the SLO");
}

/// Every rate-bearing arrival shape pins its documented mean offered
/// QPS over a long seeded horizon: Poisson and bursty at their
/// long-run rates, diurnal at (base+peak)/2 across full periods, ramp
/// at (from+to)/2 inside its window, and spike at the base rate with
/// the flash crowd concentrated in its window.
#[test]
fn arrival_shapes_pin_mean_offered_qps() {
    let arrivals = |arrival: Arrival, n: u64| -> Vec<f64> {
        let reqs = WorkloadSpec::new(n).arrival(arrival).seed(101).generate().unwrap();
        reqs.iter().map(|r| r.arrival).collect()
    };
    let mean = |ts: &[f64]| ts.len() as f64 / ts.last().unwrap();

    for r in arrivals(Arrival::AtOnce, 50) {
        assert_eq!(r, 0.0, "AtOnce arrives at t=0");
    }
    let m = mean(&arrivals(Arrival::Poisson { qps: 4.0 }, 2000));
    assert!((m - 4.0).abs() / 4.0 < 0.1, "poisson mean {m:.2} != 4");
    // bursty long-run mean is the duty-cycled rate: 8 * 2/(2+6) = 2
    let m = mean(&arrivals(Arrival::Bursty { qps: 8.0, on_s: 2.0, off_s: 6.0 }, 2000));
    assert!((m - 2.0).abs() / 2.0 < 0.1, "bursty mean {m:.2} != 2");
    // diurnal over ~10 full periods: (2+6)/2 = 4
    let d = Arrival::Diurnal { base_qps: 2.0, peak_qps: 6.0, period_s: 50.0 };
    let m = mean(&arrivals(d, 2000));
    assert!((m - 4.0).abs() / 4.0 < 0.1, "diurnal mean {m:.2} != 4");
    // ramp measured inside its window (the rate holds at to_qps after):
    // 430 of the ~500 arrivals the 100 s window carries, mean ~(1+9)/2
    let ts = arrivals(Arrival::Ramp { from_qps: 1.0, to_qps: 9.0, over_s: 100.0 }, 430);
    assert!(*ts.last().unwrap() <= 100.0, "430 arrivals fit the ramp window");
    let m = mean(&ts);
    assert!((m - 5.0).abs() / 5.0 < 0.1, "ramp mean {m:.2} != 5");
    // spike: base-rate mean outside the window, the crowd inside it
    let ts = arrivals(
        Arrival::Spike { base_qps: 2.0, spike_qps: 20.0, at_s: 60.0, dur_s: 10.0 },
        500,
    );
    assert!(ts.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
    let in_window = ts.iter().filter(|&&t| (60.0..70.0).contains(&t)).count();
    assert!(
        (150..=250).contains(&in_window),
        "expected ~200 of 500 arrivals in the 10 s spike window, got {in_window}"
    );
    let outside = (500 - in_window) as f64 / (ts.last().unwrap() - 10.0);
    assert!((outside - 2.0).abs() / 2.0 < 0.25, "off-spike rate {outside:.2} != 2");
}

/// A bursty process with a zero off-phase *is* Poisson: same draws from
/// the arrival stream, bit-identical request lists.
#[test]
fn bursty_with_zero_off_phase_is_poisson_bit_for_bit() {
    let p = WorkloadSpec::new(400)
        .arrival(Arrival::Poisson { qps: 3.0 })
        .seed(77)
        .generate()
        .unwrap();
    let b = WorkloadSpec::new(400)
        .arrival(Arrival::Bursty { qps: 3.0, on_s: 5.0, off_s: 0.0 })
        .seed(77)
        .generate()
        .unwrap();
    assert_eq!(p.len(), b.len());
    for (x, y) in p.iter().zip(b.iter()) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
        assert_eq!((x.input_len, x.output_len), (y.input_len, y.output_len));
    }
}

/// `with_offered_qps` preserves each new shape: diurnal keeps its
/// peak:base ratio and period, ramp keeps its to:from ratio and
/// duration, spike keeps its spike:base ratio and window — only the
/// overall level moves.
#[test]
fn rescaling_preserves_shaped_arrivals() {
    let base = WorkloadSpec::new(64);
    let d = base
        .clone()
        .arrival(Arrival::Diurnal { base_qps: 2.0, peak_qps: 10.0, period_s: 300.0 })
        .with_offered_qps(12.0)
        .unwrap();
    match d.arrival {
        Arrival::Diurnal { base_qps, peak_qps, period_s } => {
            assert_eq!(period_s, 300.0);
            assert!((peak_qps / base_qps - 5.0).abs() < 1e-9, "peak:base ratio kept");
            assert!(((base_qps + peak_qps) / 2.0 - 12.0).abs() < 1e-9);
        }
        other => panic!("diurnal shape lost: {other:?}"),
    }
    assert!((d.offered_qps().unwrap() - 12.0).abs() < 1e-9);
    let r = base
        .clone()
        .arrival(Arrival::Ramp { from_qps: 1.0, to_qps: 4.0, over_s: 30.0 })
        .with_offered_qps(10.0)
        .unwrap();
    match r.arrival {
        Arrival::Ramp { from_qps, to_qps, over_s } => {
            assert_eq!(over_s, 30.0);
            assert!((to_qps / from_qps - 4.0).abs() < 1e-9, "endpoint ratio kept");
            assert!(((from_qps + to_qps) / 2.0 - 10.0).abs() < 1e-9);
        }
        other => panic!("ramp shape lost: {other:?}"),
    }
    let s = base
        .clone()
        .arrival(Arrival::Spike { base_qps: 2.0, spike_qps: 20.0, at_s: 60.0, dur_s: 10.0 })
        .with_offered_qps(8.0)
        .unwrap();
    match s.arrival {
        Arrival::Spike { base_qps, spike_qps, at_s, dur_s } => {
            assert_eq!((at_s, dur_s), (60.0, 10.0), "window kept");
            assert!((spike_qps / base_qps - 10.0).abs() < 1e-9, "spike:base ratio kept");
            assert!((base_qps - 8.0).abs() < 1e-9, "spike offered load is the base rate");
        }
        other => panic!("spike shape lost: {other:?}"),
    }
}

/// The sweep table covers the grid and degrades monotonically enough to
/// read: goodput never exceeds throughput at any point.
#[test]
fn sweep_table_covers_grid_with_goodput_bounds() {
    let (plat, cfg) = a800_7b();
    let base = WorkloadSpec::new(40);
    let slo = SloSpec::interactive();
    let grid = qps_grid(0.5, 8.0, 4);
    let t = sweep_load(&plat, &cfg, &EngineSpec::lightllm(), &base, &grid, &slo).unwrap();
    assert_eq!(t.n_rows(), 4);
    for qps in grid {
        let r = simulate_requests(
            &plat,
            &cfg,
            &EngineSpec::lightllm(),
            &base.clone().arrival(Arrival::Poisson { qps }).generate().unwrap(),
        )
        .unwrap();
        assert!(r.goodput(&slo) <= r.throughput() + 1e-9);
        assert!((0.0..=1.0).contains(&r.slo_attainment(&slo)));
    }
}
