//! End-to-end tests for disaggregated prefill/decode serving (ISSUE 10):
//! the combined-pool + chunking-off spelling is bit-for-bit the existing
//! cluster simulator, requests are conserved across the KV handoff,
//! handoff bytes scale with the KV precision, a seeded scenario where a
//! disaggregated fleet dominates the chunked monolithic fleet on the
//! TTFT tail at equal GPUs, and a pool-ratio `autotune-serve` point
//! replayed through the disaggregated simulator meets the SLO it was
//! selected under.

use llm_perf_lab::config::{Arrival, LengthDist, LlamaConfig, SloSpec, WorkloadSpec};
use llm_perf_lab::hw::{Platform, PlatformId};
use llm_perf_lab::search::{autotune_serve, ReplicaSpace, SearchBudget};
use llm_perf_lab::serve::request::Request;
use llm_perf_lab::serve::{
    kv_handoff_bytes_per_token, simulate_cluster, simulate_disagg, Balancer, ClusterSpec,
    DisaggSpec, EngineSpec, KvPrecision,
};

/// Monolithic equivalence, pinned bit for bit: a `DisaggSpec` with zero
/// prefill replicas and no chunking IS the existing replica cluster —
/// same makespan, iteration counts, and per-request records under every
/// balancing policy.  This is the determinism contract DESIGN.md
/// §Disaggregation promises, so it compares raw f64 bits, not epsilons.
#[test]
fn combined_pool_without_chunking_is_the_cluster_simulator_bit_for_bit() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let engine = EngineSpec::vllm();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let reqs = WorkloadSpec::new(80)
        .arrival(Arrival::Poisson { qps: 5.0 })
        .input(LengthDist::log_normal(512.0, 0.6))
        .output(LengthDist::log_normal(96.0, 0.8))
        .seed(19)
        .generate()
        .unwrap();
    for balancer in Balancer::ALL {
        let cluster = ClusterSpec::new(3, plan, balancer).seed(7);
        let mono = simulate_cluster(&plat, &cfg, &engine, &cluster, &reqs);
        let spec = DisaggSpec::new(0, 3, plan, balancer).seed(7);
        assert!(!spec.disaggregated());
        assert_eq!(spec.total_gpus(), cluster.total_gpus());
        let dis = simulate_disagg(&plat, &cfg, &engine, &spec, &reqs);
        assert_eq!(dis.handoffs, 0, "{}", balancer.label());
        assert!(dis.prefill.is_empty());
        assert_eq!(dis.merged.makespan.to_bits(), mono.merged.makespan.to_bits());
        assert_eq!(dis.merged.decode_iters, mono.merged.decode_iters);
        assert_eq!(dis.merged.prefill_iters, mono.merged.prefill_iters);
        assert_eq!(dis.merged.preemptions, mono.merged.preemptions);
        assert_eq!(dis.merged.completions.len(), mono.merged.completions.len());
        for (a, b) in dis.merged.completions.iter().zip(mono.merged.completions.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.finish.to_bits(), b.finish.to_bits());
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        }
        assert_eq!(dis.decode.len(), mono.replicas.len());
        for (a, b) in dis.decode.iter().zip(mono.replicas.iter()) {
            assert_eq!(a.requests, b.requests);
            assert_eq!(a.completions, b.completions);
        }
    }
}

/// Every request is rejected exactly once or handed off exactly once
/// and completes exactly once — the two-stage dispatcher must neither
/// drop nor duplicate across the prefill pool, the handoff, and the
/// decode pool, even with an unservable giant in the stream.
#[test]
fn requests_are_conserved_across_the_kv_handoff() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let engine = EngineSpec::vllm();
    let plan = engine.plan(&plat, &cfg).unwrap();
    let mut reqs = WorkloadSpec::new(90)
        .arrival(Arrival::Poisson { qps: 6.0 })
        .input(LengthDist::log_normal(400.0, 0.8))
        .output(LengthDist::log_normal(64.0, 1.0))
        .seed(13)
        .generate()
        .unwrap();
    // a prompt beyond any prefill budget: rejected once, never shipped
    reqs.push(Request { id: 1000, input_len: 1_000_000, output_len: 8, arrival: 2.0 });
    let spec = DisaggSpec::new(2, 2, plan, Balancer::JoinShortestQueue).seed(5);
    let r = simulate_disagg(&plat, &cfg, &engine, &spec, &reqs);
    assert_eq!(r.merged.rejected, 1);
    assert_eq!(r.merged.completions.len() + r.merged.rejected as usize, reqs.len());
    assert_eq!(r.handoffs, r.merged.completions.len() as u64,
               "one handoff per prompt that reached decode");
    let mut ids: Vec<u64> = r.merged.completions.iter().map(|c| c.id).collect();
    ids.sort();
    ids.dedup();
    assert_eq!(ids.len(), reqs.len() - 1, "duplicate or lost completions");
    // stage-level bookkeeping agrees with the merged view
    let routed: u64 = r.prefill.iter().map(|s| s.requests).sum();
    assert_eq!(routed, reqs.len() as u64, "stage-1 dispatch covers every arrival");
    let decoded: u64 = r.decode.iter().map(|s| s.completions).sum();
    assert_eq!(decoded, r.merged.completions.len() as u64);
    let prefilled: u64 = r.prefill.iter().map(|s| s.tokens).sum();
    let expected: u64 = reqs.iter().filter(|q| q.id != 1000).map(|q| q.input_len).sum();
    assert_eq!(prefilled, expected, "every admitted prompt token is prefilled exactly once");
}

/// The handoff is priced on the bytes the wire actually moves: int4 KV
/// ships exactly a quarter of the fp16 bytes for the same prompts, and
/// the per-token constant matches the config's real GQA geometry.
#[test]
fn handoff_bytes_scale_with_kv_precision() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let bpt16 = kv_handoff_bytes_per_token(&cfg, KvPrecision::Fp16);
    let bpt4 = kv_handoff_bytes_per_token(&cfg, KvPrecision::Int4);
    assert_eq!(bpt16, 4.0 * bpt4);
    let reqs = WorkloadSpec::new(40)
        .arrival(Arrival::Poisson { qps: 4.0 })
        .input(LengthDist::log_normal(600.0, 0.5))
        .seed(5)
        .generate()
        .unwrap();
    let run = |engine: &EngineSpec| {
        let plan = engine.plan(&plat, &cfg).unwrap();
        let spec = DisaggSpec::new(1, 1, plan, Balancer::RoundRobin).seed(3);
        simulate_disagg(&plat, &cfg, engine, &spec, &reqs)
    };
    let fp16 = run(&EngineSpec::vllm());
    let int4 = run(&EngineSpec::vllm().with_kv_precision(KvPrecision::Int4));
    assert_eq!(fp16.handoffs, int4.handoffs);
    assert!(int4.handoff_bytes < fp16.handoff_bytes);
    // same prompts, same token counts — the totals differ by exactly
    // the precision ratio (summation order may differ, so allow ulps)
    let ratio_err = (fp16.handoff_bytes - 4.0 * int4.handoff_bytes).abs();
    assert!(ratio_err < 1e-6 * fp16.handoff_bytes,
            "fp16 {} != 4x int4 {}", fp16.handoff_bytes, int4.handoff_bytes);
    // a lighter handoff is also a faster one on the same fabric
    assert!(int4.mean_handoff_time < fp16.mean_handoff_time);
}

/// Acceptance (ISSUE 10): a seeded scenario where the disaggregated
/// fleet dominates the monolithic fleet on TTFT p99 at equal GPUs.
///
/// The monolithic fleet runs chunked prefill — the configuration that
/// protects TPOT from prompt stalls — so every 2048-token prompt pays
/// 16 iterations of (decode iteration + 128-token chunk) before its
/// first token: the chunk scheduler's explicit TTFT↔TPOT trade.  The
/// disaggregated fleet needs no chunking at all: its prefill pool runs
/// pure batched prefill with zero decode co-scheduling, so per-prompt
/// prefill service time is a fraction of the monolithic replica's
/// chunked TTFT path, and the tail follows.  Both fleets use 4 GPUs
/// (4×TP1 monolithic vs 3 prefill + 1 decode at TP1).
#[test]
fn disagg_dominates_chunked_monolithic_on_ttft_p99_at_equal_gpus() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let reqs = WorkloadSpec::new(140)
        .arrival(Arrival::Poisson { qps: 2.0 })
        .input(LengthDist::Fixed(2048))
        .output(LengthDist::Fixed(256))
        .seed(29)
        .generate()
        .unwrap();
    let mono_engine = EngineSpec::vllm().with_chunked_prefill(Some(128));
    let mono_plan = mono_engine.plan(&plat, &cfg).unwrap();
    let cluster = ClusterSpec::new(4, mono_plan, Balancer::RoundRobin).seed(11);
    assert_eq!(cluster.total_gpus(), 4);
    let mono = simulate_cluster(&plat, &cfg, &mono_engine, &cluster, &reqs);

    let dis_engine = EngineSpec::vllm();
    let dis_plan = dis_engine.plan(&plat, &cfg).unwrap();
    let spec = DisaggSpec::new(3, 1, dis_plan, Balancer::RoundRobin).seed(11);
    assert_eq!(spec.total_gpus(), 4);
    let dis = simulate_disagg(&plat, &cfg, &dis_engine, &spec, &reqs);

    assert_eq!(mono.merged.completions.len(), reqs.len());
    assert_eq!(dis.merged.completions.len(), reqs.len());
    assert_eq!(dis.handoffs, reqs.len() as u64);
    let (mono_p99, dis_p99) =
        (mono.merged.ttft_cdf().quantile(0.99), dis.merged.ttft_cdf().quantile(0.99));
    assert!(dis_p99 < mono_p99,
            "disagg ttft p99 {dis_p99:.2}s !< chunked monolithic {mono_p99:.2}s at 4 GPUs");
    // the win is the whole tail, not one quantile
    let (mono_p90, dis_p90) =
        (mono.merged.ttft_cdf().quantile(0.9), dis.merged.ttft_cdf().quantile(0.9));
    assert!(dis_p90 < mono_p90,
            "disagg ttft p90 {dis_p90:.2}s !< chunked monolithic {mono_p90:.2}s");
}

/// Acceptance (ISSUE 10): `autotune-serve` exposes the prefill:decode
/// pool-ratio axis, and replaying a chosen pool-ratio point through the
/// disaggregated simulator at its measured capacity meets the SLO it
/// was selected under (the bisection's last passing probe is exactly
/// reproducible — same seed, same re-armed workload).
#[test]
fn pool_ratio_autotune_point_replays_and_meets_its_slo() {
    let plat = Platform::get(PlatformId::A800);
    let cfg = LlamaConfig::llama2_7b();
    let base = WorkloadSpec::new(48).seed(9);
    let slo = SloSpec::new(0.9, 4.0, 0.25);
    let rep = ReplicaSpace {
        max_replicas: 2,
        gpu_budget: Some(2),
        balancer: Balancer::RoundRobin,
        disagg: true,
    };
    // bracket ceiling far above any 2-GPU capacity so nothing saturates
    // and the early-prune never skips the disagg candidate
    let search = autotune_serve(&plat, &cfg, &[EngineSpec::vllm()], &base, &slo, None,
                                (0.5, 512.0), rep, SearchBudget::default())
        .unwrap();
    let dis = search
        .evals
        .iter()
        .find(|e| e.cand.prefill_replicas > 0)
        .expect("--disagg must put a pool split in the costed space");
    assert_eq!(dis.cand.label(), "vLLM TP1 1p+1d");
    assert_eq!(dis.gpus, 2);
    let q = dis.max_qps.expect("a 2-GPU 7B split must be servable at the bracket floor");
    let spec = DisaggSpec::new(dis.cand.prefill_replicas, dis.cand.replicas, dis.cand.plan,
                               rep.balancer)
        .seed(base.seed)
        .chunk_tokens(dis.cand.engine.chunked_prefill);
    let reqs = base.with_offered_qps(q).unwrap().generate().unwrap();
    let replay = simulate_disagg(&plat, &cfg, &dis.cand.engine, &spec, &reqs);
    assert!(replay.handoffs > 0);
    assert!(replay.merged.meets_slo(&slo),
            "pool-ratio point {} misses the SLO it was selected under at {q:.2} QPS",
            dis.cand.label());
}
