//! Integration tests over the real PJRT runtime + AOT artifacts.
//! These need `make artifacts` (micro + tiny models); they are skipped
//! with a clear message if the artifacts are missing.

use llm_perf_lab::engine::{EngineCore, GenRequest, Server};
use llm_perf_lab::runtime::Runtime;
use llm_perf_lab::trainer::Trainer;

fn artifacts_ready() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
    }
    ok
}

#[test]
fn manifest_loads_and_entries_compile() {
    if !artifacts_ready() { return; }
    let rt = Runtime::open("artifacts").unwrap();
    assert!(rt.manifest.models.iter().any(|m| m.name == "micro"));
    for entry in ["forward", "train_step", "insert_request", "decode_step"] {
        rt.compile_entry("micro", entry)
            .unwrap_or_else(|e| panic!("compile micro/{entry}: {e}"));
    }
}

#[test]
fn params_match_manifest_count() {
    if !artifacts_ready() { return; }
    let rt = Runtime::open("artifacts").unwrap();
    let params = rt.load_params("micro").unwrap();
    assert_eq!(params.len(), 12, "python PARAM_NAMES order has 12 tensors");
    let total: usize = params.iter().map(|p| p.element_count()).sum();
    assert_eq!(total as u64, rt.model_info("micro").unwrap().params);
}

#[test]
fn forward_runs_and_logits_shape() {
    if !artifacts_ready() { return; }
    let rt = Runtime::open("artifacts").unwrap();
    let info = rt.model_info("micro").unwrap();
    let exe = rt.compile_entry("micro", "forward").unwrap();
    let params = rt.load_params("micro").unwrap();
    let tokens: Vec<i32> = (0..(info.train_batch * info.seq) as i32)
        .map(|t| t % info.vocab as i32)
        .collect();
    let tok = llm_perf_lab::runtime::client::i32_literal(
        &tokens, &[info.train_batch as i64, info.seq as i64]).unwrap();
    let mut args: Vec<&xla::Literal> = params.iter().collect();
    args.push(&tok);
    let out = rt.run(&exe, &args).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].element_count() as u64,
               info.train_batch * info.seq * info.vocab);
    let logits: Vec<f32> = out[0].to_vec().unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
}

#[test]
fn trainer_reduces_loss_micro() {
    if !artifacts_ready() { return; }
    let mut tr = Trainer::new("artifacts", "micro", 2e-3, 1).unwrap();
    let initial_expected = (tr.info.vocab as f32).ln();
    let first = tr.step().unwrap();
    assert!((first - initial_expected).abs() < 1.0,
            "first loss {first} should be near ln(V)={initial_expected}");
    for _ in 0..24 {
        tr.step().unwrap();
    }
    let last = tr.history.last().unwrap().loss;
    assert!(last < first - 0.3, "loss should fall: {first} -> {last}");
}

#[test]
fn engine_generates_deterministically() {
    if !artifacts_ready() { return; }
    let run_once = || {
        let mut core = EngineCore::new("artifacts", "micro").unwrap();
        let req = GenRequest { id: 0, prompt: vec![1, 2, 3, 4, 5], max_new: 8 };
        let outs = core.run_batch(std::slice::from_ref(&req)).unwrap();
        outs[0].tokens.clone()
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "greedy decoding must be deterministic");
    assert_eq!(a.len(), 8);
}

#[test]
fn engine_continuous_batching_oversubscribed() {
    if !artifacts_ready() { return; }
    let mut core = EngineCore::new("artifacts", "micro").unwrap();
    let n = core.n_slots() * 3; // more requests than slots
    let reqs: Vec<GenRequest> = (0..n as u64)
        .map(|i| GenRequest {
            id: i,
            prompt: vec![(i % 200) as i32 + 1; 6],
            max_new: 5,
        })
        .collect();
    let outs = core.run_batch(&reqs).unwrap();
    assert_eq!(outs.len(), n);
    for o in &outs {
        assert_eq!(o.tokens.len(), 5);
        assert!(o.ttft <= o.latency);
    }
}

#[test]
fn decode_matches_forward_teacher_forced() {
    // the real-runtime counterpart of the python prefill/decode test:
    // greedy continuation from insert_request must equal running decode
    // steps one by one (state is carried entirely in the Rust-owned cache)
    if !artifacts_ready() { return; }
    let mut c1 = EngineCore::new("artifacts", "micro").unwrap();
    let prompt: Vec<i32> = (1..=10).collect();
    let req = GenRequest { id: 7, prompt: prompt.clone(), max_new: 6 };
    let o1 = c1.run_batch(std::slice::from_ref(&req)).unwrap();
    // same request admitted alongside others must produce identical tokens
    let mut c2 = EngineCore::new("artifacts", "micro").unwrap();
    let mut reqs = vec![GenRequest { id: 0, prompt: vec![42; 8], max_new: 6 }];
    reqs.push(req);
    let o2 = c2.run_batch(&reqs).unwrap();
    let t1 = &o1[0].tokens;
    let t2 = &o2.iter().find(|o| o.id == 7).unwrap().tokens;
    assert_eq!(t1, t2, "slot isolation: co-batching must not change output");
}

#[test]
fn threaded_server_serves_burst() {
    if !artifacts_ready() { return; }
    let server = std::sync::Arc::new(Server::start("artifacts", "micro").unwrap());
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let s = std::sync::Arc::clone(&server);
        handles.push(std::thread::spawn(move || {
            s.submit(vec![(i as i32) + 1; 5], 4, i).unwrap().wait().unwrap()
        }));
    }
    let outs: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(outs.len(), 6);
    for o in outs {
        assert_eq!(o.tokens.len(), 4);
    }
}

#[test]
fn calibration_micro_kernels_run() {
    if !artifacts_ready() { return; }
    let rt = Runtime::open("artifacts").unwrap();
    // one representative of each op family
    for name in ["gemm_m128_n256_k256", "attn_naive_s128", "attn_flash_s128",
                 "rmsnorm_pallas", "rope", "softmax"] {
        let t = llm_perf_lab::calibrate::time_micro(&rt, name, 2)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(t.seconds > 0.0 && t.seconds < 30.0, "{name}: {}", t.seconds);
    }
}
