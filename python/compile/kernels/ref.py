"""Pure-jnp reference oracle for every kernel in this package.

These are the "naive" implementations in the paper's terms (Table VIII
compares naive attention against FlashAttention).  They are the ground
truth for pytest/hypothesis checks of the Pallas kernels and for the
autodiff (custom_vjp backward) rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, causal: bool = True, kv_len=None, scale=None):
    """Naive attention.  q,k,v: (..., S, D) with matching leading dims.

    ``kv_len``: optional int32 scalar/array — keys at index >= kv_len are
    masked (used for padded prefill and KV-cache decode).
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(d))
    s = jnp.einsum("...qd,...kd->...qk", q, k) * scale
    s_len, k_len = q.shape[-2], k.shape[-2]
    if causal:
        q_pos = jnp.arange(s_len)[:, None] + (k_len - s_len)
        k_pos = jnp.arange(k_len)[None, :]
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)
    if kv_len is not None:
        k_pos = jnp.arange(k_len)
        s = jnp.where(k_pos[None, :] < kv_len, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v)


def rmsnorm(x, w, eps: float = 1e-5):
    """RMS normalization over the last axis (Zhang & Sennrich, 2019)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu_mlp(x, w_gate, w_up, w_down):
    """Llama MLP: down( silu(x @ gate) * (x @ up) )."""
    return (silu(x @ w_gate) * (x @ w_up)) @ w_down


def rope_freqs(dim: int, theta: float = 10000.0):
    """Inverse frequencies for rotary embedding, shape (dim//2,)."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2).astype(jnp.float32) / dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """Rotary position embedding (rotate-half convention).

    x: (..., S, D) with D even; positions: broadcastable to (..., S).
    """
    d = x.shape[-1]
    assert d % 2 == 0, "rope head dim must be even"
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_xent(logits, targets):
    """Mean cross-entropy; logits (..., V), targets (...,) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
