"""L1: fused RMSNorm as a Pallas kernel.

The paper's module-wise analysis (Table VI) shows RMSNorm taking ~9-11% of
decoder time because the naive lowering issues several element-wise
kernels (square, mean, rsqrt, mul, mul).  The fused kernel reads x once,
keeps the row statistics in VMEM and writes the normalized output once —
the kernel-fusion opportunity §VI-B calls out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = True):
    """Fused RMSNorm over the last axis.  x: (..., d), w: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = x.size // d
    xf = x.reshape(n, d)
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    rows = xf.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out[:n].reshape(orig_shape)
