"""L1 Pallas kernels + pure-jnp reference oracle."""

from . import flash_attention, ref, rmsnorm  # noqa: F401
