"""L1: FlashAttention as a Pallas kernel (the paper's Table VIII subject).

GPU→TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernel's
HBM↔SRAM tiling with threadblocks becomes an HBM↔VMEM schedule expressed
through ``BlockSpec``: each program instance owns one (block_q × d) query
tile and streams (block_k × d) key/value tiles through VMEM while keeping
the online-softmax state (m, l, acc) in registers/VMEM scratch.  The IO
complexity is the FlashAttention one — O(S²·d/M) HBM traffic with M the
VMEM budget — and the matmuls inside the tile target the MXU.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see
/opt/xla-example/README.md); interpret mode lowers to plain HLO so the
kernel runs anywhere, including the Rust PJRT client.

The backward pass is a custom_vjp implemented with the standard flash
backward algebra in pure jnp (recompute p from q,k,v — no stored S×S
attention matrix in the forward residuals' critical path).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, kv_blocks: int,
                  scale: float, causal: bool, kv_len: int):
    """One program instance: one (block_q, d) query tile of one (batch*head)."""
    q = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
    bq, d = q.shape
    qi = pl.program_id(1)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)  # global query positions

    def body(ki, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(ki * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (bq, bk) — MXU tile matmul
        k_pos = ki * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = k_pos[None, :] < kv_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        s = jnp.where(mask, s, ref.NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_cur, l_cur, acc

    m0 = jnp.full((bq,), ref.NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, d), jnp.float32)
    # Causal: query tile qi never attends past k block (qi+1)*bq — skip the rest.
    if causal:
        hi = jnp.minimum(((qi + 1) * bq + block_k - 1) // block_k, kv_blocks)
    else:
        hi = kv_blocks
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, acc0))
    l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows (padded queries)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def flash_attention_fwd_impl(q, k, v, causal: bool = True,
                             block_q: int = DEFAULT_BLOCK_Q,
                             block_k: int = DEFAULT_BLOCK_K,
                             interpret: bool = True):
    """Pallas forward. q,k,v: (B, H, S, D) f32.  Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    assert k.shape == v.shape == (b, h, s, d), "flash_attention: q/k/v mismatch"
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    qf = _pad_to(q.reshape(b * h, s, d), 1, block_q)
    kf = _pad_to(k.reshape(b * h, s, d), 1, block_k)
    vf = _pad_to(v.reshape(b * h, s, d), 1, block_k)
    sq, sk = qf.shape[1], kf.shape[1]
    grid = (b * h, sq // block_q)
    kernel = functools.partial(
        _flash_kernel,
        block_k=block_k,
        kv_blocks=sk // block_k,
        scale=scale,
        causal=causal,
        kv_len=s,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, sk, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, sk, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :s, :].reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    """Flash attention with a flash-algebra backward (custom_vjp)."""
    return flash_attention_fwd_impl(q, k, v, causal)


def _fwd(q, k, v, causal):
    o = flash_attention_fwd_impl(q, k, v, causal)
    return o, (q, k, v)


def _bwd(causal, res, do):
    """Standard flash backward: recompute p; no S×S residual stored."""
    q, k, v = res
    d = q.shape[-1]
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        s_len, k_len = q.shape[-2], k.shape[-2]
        q_pos = jnp.arange(s_len)[:, None]
        k_pos = jnp.arange(k_len)[None, :]
        s = jnp.where(k_pos <= q_pos, s, ref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dof = do.astype(jnp.float32)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, v.astype(jnp.float32))
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k.astype(jnp.float32)) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q.astype(jnp.float32)) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_fwd, _bwd)


def vmem_bytes(block_q: int, block_k: int, d: int, itemsize: int = 4) -> int:
    """Modeled VMEM footprint of one program instance (DESIGN.md §Perf L1)."""
    q_tile = block_q * d
    kv_tiles = 2 * block_k * d
    state = block_q * (d + 2)  # acc + (m, l)
    out = block_q * d
    return (q_tile + kv_tiles + state + out) * itemsize


def hbm_traffic_bytes(s: int, d: int, block_q: int, itemsize: int = 4) -> int:
    """Modeled HBM traffic per head: Q+O once, K+V once per query tile."""
    q_blocks = -(-s // block_q)
    return itemsize * (2 * s * d + q_blocks * 2 * s * d)
