"""L2: Llama2-style decoder-only model in JAX (build-time only).

The model follows the paper's §II-A / §III-B module inventory exactly:
Embedding → N × LlamaDecoderLayer(RMSNorm, QKV proj + RoPE, attention,
O proj, SwiGLU MLP, RMSNorm) → final RMSNorm → LM head.  Attention is the
L1 Pallas flash kernel (custom_vjp) so it lowers into the same HLO that
the Rust runtime executes.

Parameters are a fixed-order list of 12 stacked arrays (layers scanned
with lax.scan) so the Rust side can feed PJRT buffers positionally:

    0 embed      (V, d)         6 w_down  (L, ff, d)
    1 wq (L,d,d) 7 w_up    (L, d, ff)
    2 wk (L,d,d) 8 rms_attn(L, d)
    3 wv (L,d,d) 9 rms_mlp (L, d)
    4 wo (L,d,d) 10 final_norm (d,)
    5 w_gate (L, d, ff)          11 lm_head (d, V)

Entry points lowered by aot.py (all pure, all static-shape):
  forward(params, tokens)                      -> logits
  train_step(params, m, v, step, lr, tokens)   -> (params', m', v', step', loss)
  insert_request(params, kc, vc, slot, prompt, prompt_len) -> (kc', vc', last_logits)
  decode_step(params, kc, vc, tokens, positions) -> (logits, kc', vc')
"""

from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.flash_attention import flash_attention

PARAM_NAMES = [
    "embed", "wq", "wk", "wv", "wo", "w_gate", "w_down", "w_up",
    "rms_attn", "rms_mlp", "final_norm", "lm_head",
]
NUM_PARAMS = len(PARAM_NAMES)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model + workload shape description (mirrored in Rust config/)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int            # training sequence length
    train_batch: int
    prompt_len: int     # serving: padded prefill length
    max_seq: int        # serving: KV-cache capacity per slot
    dec_batch: int      # serving: decode slots
    rope_theta: float = 10000.0
    use_flash: bool = True

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self):
        v, d, l, ff = self.vocab, self.d_model, self.n_layers, self.d_ff
        return {
            "embed": (v, d),
            "wq": (l, d, d), "wk": (l, d, d), "wv": (l, d, d), "wo": (l, d, d),
            "w_gate": (l, d, ff), "w_down": (l, ff, d), "w_up": (l, d, ff),
            "rms_attn": (l, d), "rms_mlp": (l, d),
            "final_norm": (d,), "lm_head": (d, v),
        }

    def param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for s in self.param_shapes().values())


PRESETS = {
    # test-size model: fast enough for hypothesis sweeps
    "micro": ModelConfig("micro", vocab=256, d_model=64, n_layers=2, n_heads=4,
                         d_ff=176, seq=32, train_batch=4, prompt_len=16,
                         max_seq=64, dec_batch=4),
    # default artifact: the end-to-end train/serve demo model
    "tiny": ModelConfig("tiny", vocab=2048, d_model=256, n_layers=4, n_heads=8,
                        d_ff=688, seq=128, train_batch=8, prompt_len=64,
                        max_seq=512, dec_batch=8),
    # ~100M-parameter transformer for the e2e training validation
    "m100": ModelConfig("m100", vocab=8192, d_model=768, n_layers=12, n_heads=12,
                        d_ff=2048, seq=256, train_batch=4, prompt_len=64,
                        max_seq=512, dec_batch=4),
}


def init_params(cfg: ModelConfig, key) -> List[jax.Array]:
    """Normal(0, 0.02) init, ones for norms — returned in PARAM_NAMES order."""
    shapes = cfg.param_shapes()
    params = []
    for name in PARAM_NAMES:
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.startswith("rms") or name == "final_norm":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
    return params


def _split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _attention(cfg: ModelConfig, q, k, v):
    if cfg.use_flash:
        return flash_attention(q, k, v, True)
    return ref.attention(q, k, v, causal=True)


def _decoder_layer(cfg: ModelConfig, h, layer, positions):
    """One LlamaDecoderLayer.  h: (B, S, d)."""
    wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m = layer
    x = ref.rmsnorm(h, rms_a)
    q = ref.apply_rope(_split_heads(x @ wq, cfg.n_heads), positions, cfg.rope_theta)
    k = ref.apply_rope(_split_heads(x @ wk, cfg.n_heads), positions, cfg.rope_theta)
    v = _split_heads(x @ wv, cfg.n_heads)
    attn = _merge_heads(_attention(cfg, q, k, v)) @ wo
    h = h + attn
    x = ref.rmsnorm(h, rms_m)
    return h + ref.swiglu_mlp(x, w_gate, w_up, w_down)


def forward(cfg: ModelConfig, params: List[jax.Array], tokens) -> jax.Array:
    """Full forward pass.  tokens: (B, S) int32 → logits (B, S, V)."""
    embed, wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m, fnorm, head = params
    b, s = tokens.shape
    h = embed[tokens]
    # shape (1, 1, S): broadcasts against (B, H, S, D) inside apply_rope
    positions = jnp.arange(s, dtype=jnp.int32)[None, None, :]

    def body(h, layer):
        return _decoder_layer(cfg, h, layer, positions), None

    h, _ = jax.lax.scan(body, h, (wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m))
    h = ref.rmsnorm(h, fnorm)
    return h @ head


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token causal-LM cross entropy over tokens (B, S)."""
    logits = forward(cfg, params, tokens)
    return ref.softmax_xent(logits[:, :-1, :], tokens[:, 1:])


# ---------------------------------------------------------------- training

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.95, 1e-8


def train_step(cfg: ModelConfig, params, m, v, step, lr, tokens):
    """One AdamW-free Adam step.  All state passed in/out positionally."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    step = step + 1.0
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        update = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
        new_params.append(p - lr * update)
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, step, loss


def init_opt_state(params):
    zeros = [jnp.zeros_like(p) for p in params]
    return zeros, [jnp.zeros_like(p) for p in params], jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- serving

def cache_shape(cfg: ModelConfig):
    return (cfg.n_layers, cfg.dec_batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


def init_cache(cfg: ModelConfig):
    shape = cache_shape(cfg)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def insert_request(cfg: ModelConfig, params, k_cache, v_cache, slot, prompt, prompt_len):
    """Prefill one request into cache slot ``slot``.

    prompt: (prompt_len_padded,) int32, right-padded.  Runs a full B=1
    forward, writes K/V for positions [0, cfg.prompt_len) into the slot
    (padded tail positions carry garbage keys but are masked at decode by
    ``positions``), and returns the logits at position prompt_len-1.
    """
    embed, wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m, fnorm, head = params
    p = cfg.prompt_len
    h = embed[prompt][None, :, :]  # (1, P, d)
    positions = jnp.arange(p, dtype=jnp.int32)[None, None, :]

    def body(h, layer):
        wq_l, wk_l, wv_l, wo_l, wg_l, wd_l, wu_l, ra_l, rm_l = layer
        x = ref.rmsnorm(h, ra_l)
        q = ref.apply_rope(_split_heads(x @ wq_l, cfg.n_heads), positions, cfg.rope_theta)
        k = ref.apply_rope(_split_heads(x @ wk_l, cfg.n_heads), positions, cfg.rope_theta)
        v = _split_heads(x @ wv_l, cfg.n_heads)
        # Right-padded prompt + causal mask means real query rows never see
        # the padded tail, and padded K/V slots are overwritten sequentially
        # by decode before they can be attended — plain causal attention
        # (the Pallas flash kernel) is exact here.
        attn = _attention(cfg, q, k, v)
        h = h + _merge_heads(attn) @ wo_l
        x = ref.rmsnorm(h, rm_l)
        h = h + ref.swiglu_mlp(x, wg_l, wu_l, wd_l)
        return h, (k[0], v[0])  # (H, P, dh)

    h, (ks, vs) = jax.lax.scan(
        body, h, (wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m))
    h = ref.rmsnorm(h, fnorm)
    last = jax.lax.dynamic_slice(
        h[0], (prompt_len.astype(jnp.int32) - 1, jnp.zeros((), jnp.int32)),
        (1, cfg.d_model))[0]
    logits = last @ head  # (V,)

    # scatter the (L, H, P, dh) prefill K/V into cache slot
    pad = cfg.max_seq - p
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0)))[:, None]
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0)))[:, None]
    zero = jnp.zeros((), jnp.int32)
    idx = (zero, slot.astype(jnp.int32), zero, zero, zero)
    mask = (jnp.arange(cfg.max_seq) < prompt_len)[None, None, None, :, None]
    old_k = jax.lax.dynamic_slice(
        k_cache, idx, (cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim))
    old_v = jax.lax.dynamic_slice(
        v_cache, idx, (cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.head_dim))
    k_cache = jax.lax.dynamic_update_slice(k_cache, jnp.where(mask, ks, old_k), idx)
    v_cache = jax.lax.dynamic_update_slice(v_cache, jnp.where(mask, vs, old_v), idx)
    return k_cache, v_cache, logits


def decode_step(cfg: ModelConfig, params, k_cache, v_cache, tokens, positions):
    """One decode iteration over all slots.

    tokens: (B,) int32 current token per slot; positions: (B,) int32 index
    the token occupies.  Inactive slots just decode garbage (masked out on
    the Rust side) — the batch shape is static, as in a real continuous
    batcher's padded decode batch.
    Returns (logits (B, V), k_cache', v_cache').
    """
    embed, wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m, fnorm, head = params
    bsz = cfg.dec_batch
    h = embed[tokens][:, None, :]  # (B, 1, d)
    pos_b = positions[:, None, None]  # (B, 1, 1) -> broadcasts over heads

    def body(h, layer):
        wq_l, wk_l, wv_l, wo_l, wg_l, wd_l, wu_l, ra_l, rm_l, kc_l, vc_l = layer
        x = ref.rmsnorm(h, ra_l)
        q = ref.apply_rope(_split_heads(x @ wq_l, cfg.n_heads), pos_b, cfg.rope_theta)
        k = ref.apply_rope(_split_heads(x @ wk_l, cfg.n_heads), pos_b, cfg.rope_theta)
        v = _split_heads(x @ wv_l, cfg.n_heads)  # (B, H, 1, dh)

        def upd(cache_b, new_b, p):
            return jax.lax.dynamic_update_slice(cache_b, new_b, (0, p, 0))

        kc_l = jax.vmap(upd)(kc_l, k, positions)  # (B, H, S, dh)
        vc_l = jax.vmap(upd)(vc_l, v, positions)
        # attend over cache with per-slot kv_len = position+1
        scale = 1.0 / jnp.sqrt(jnp.float32(cfg.head_dim))
        s = jnp.einsum("bhqd,bhkd->bhqk", q, kc_l) * scale
        k_pos = jnp.arange(cfg.max_seq)[None, None, None, :]
        s = jnp.where(k_pos <= positions[:, None, None, None], s, ref.NEG_INF)
        p_attn = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", p_attn, vc_l)
        h = h + _merge_heads(attn) @ wo_l
        x = ref.rmsnorm(h, rm_l)
        h = h + ref.swiglu_mlp(x, wg_l, wu_l, wd_l)
        return h, (kc_l, vc_l)

    h, (new_k, new_v) = jax.lax.scan(
        body, h,
        (wq, wk, wv, wo, w_gate, w_down, w_up, rms_a, rms_m, k_cache, v_cache))
    h = ref.rmsnorm(h, fnorm)
    logits = h[:, 0, :] @ head  # (B, V)
    assert logits.shape == (bsz, cfg.vocab)
    return logits, new_k, new_v
