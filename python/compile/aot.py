"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Python runs ONCE, at build time (``make artifacts``), and never on the
request path.  The interchange format is **HLO text**, not a serialized
``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Outputs (under ``artifacts/``):
  manifest.txt           line-based manifest the Rust side parses
  params_<model>.bin     initial parameters, raw little-endian f32,
                         concatenated in model.PARAM_NAMES order
  <model>_<entry>.hlo.txt        model entry points
  micro_<name>.hlo.txt           operator microbenchmarks (GEMM sweep,
                                 attention naive/flash, rmsnorm, rope, …)

Usage: python -m compile.aot [--out-dir ../artifacts] [--models micro,tiny]
                             [--with-m100]
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.flash_attention import flash_attention_fwd_impl
from .kernels.rmsnorm import rmsnorm as pallas_rmsnorm

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Manifest:
    def __init__(self):
        self.lines = [f"# llm-perf-lab artifact manifest v{MANIFEST_VERSION}"]

    def add(self, kind: str, **kv):
        parts = [kind] + [f"{k}={v}" for k, v in kv.items()]
        self.lines.append(" ".join(parts))

    def write(self, path: str):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def write_params(cfg: M.ModelConfig, out_dir: str, manifest: Manifest, seed: int = 0):
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    path = os.path.join(out_dir, f"params_{cfg.name}.bin")
    offset = 0
    with open(path, "wb") as f:
        for name, p in zip(M.PARAM_NAMES, params):
            data = bytes(jnp.asarray(p, jnp.float32).tobytes())
            shape = ",".join(str(int(s)) for s in p.shape)
            manifest.add("param", model=cfg.name, name=name, dtype="f32",
                         shape=shape, offset=offset, nbytes=len(data))
            f.write(data)
            offset += len(data)
    return params


def emit_model(cfg: M.ModelConfig, out_dir: str, manifest: Manifest):
    t0 = time.time()
    shapes = cfg.param_shapes()
    p_specs = [spec(shapes[n]) for n in M.PARAM_NAMES]
    cshape = M.cache_shape(cfg)

    manifest.add(
        "config", model=cfg.name, vocab=cfg.vocab, d_model=cfg.d_model,
        n_layers=cfg.n_layers, n_heads=cfg.n_heads, d_ff=cfg.d_ff,
        head_dim=cfg.head_dim, seq=cfg.seq, train_batch=cfg.train_batch,
        prompt_len=cfg.prompt_len, max_seq=cfg.max_seq,
        dec_batch=cfg.dec_batch, params=cfg.param_count())

    def emit(entry, fn, args, n_out):
        fname = f"{cfg.name}_{entry}.hlo.txt"
        text = lower_fn(fn, args)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest.add("hlo", model=cfg.name, entry=entry, file=fname,
                     inputs=len(args), outputs=n_out)
        print(f"  {fname}: {len(text)//1024} KiB, {len(args)} in / {n_out} out")

    tok = spec((cfg.train_batch, cfg.seq), jnp.int32)

    # forward: params, tokens -> logits
    emit("forward", lambda *a: (M.forward(cfg, list(a[:M.NUM_PARAMS]), a[-1]),),
         p_specs + [tok], 1)

    # train_step: params, m, v, step, lr, tokens -> params', m', v', step', loss
    def ts(*a):
        n = M.NUM_PARAMS
        params, m, v = list(a[:n]), list(a[n:2 * n]), list(a[2 * n:3 * n])
        step, lr, tokens = a[3 * n], a[3 * n + 1], a[3 * n + 2]
        np_, nm, nv, ns, loss = M.train_step(cfg, params, m, v, step, lr, tokens)
        return tuple(np_) + tuple(nm) + tuple(nv) + (ns, loss)

    emit("train_step", ts,
         p_specs * 3 + [spec(()), spec(()), tok], 3 * M.NUM_PARAMS + 2)

    # insert_request: params, kc, vc, slot, prompt, prompt_len -> kc', vc', logits
    def ins(*a):
        n = M.NUM_PARAMS
        return M.insert_request(cfg, list(a[:n]), a[n], a[n + 1], a[n + 2],
                                a[n + 3], a[n + 4])

    emit("insert_request", ins,
         p_specs + [spec(cshape), spec(cshape), spec((), jnp.int32),
                    spec((cfg.prompt_len,), jnp.int32), spec((), jnp.int32)], 3)

    # decode_step: params, kc, vc, tokens, positions -> logits, kc', vc'
    def dec(*a):
        n = M.NUM_PARAMS
        return M.decode_step(cfg, list(a[:n]), a[n], a[n + 1], a[n + 2], a[n + 3])

    emit("decode_step", dec,
         p_specs + [spec(cshape), spec(cshape),
                    spec((cfg.dec_batch,), jnp.int32),
                    spec((cfg.dec_batch,), jnp.int32)], 3)

    print(f"  [{cfg.name}] lowered in {time.time() - t0:.1f}s "
          f"({cfg.param_count() / 1e6:.1f}M params)")


# ------------------------------------------------------------ microbenches

def emit_micro(out_dir: str, manifest: Manifest):
    """Operator microbenchmarks for calibrate/ and Tables VIII/XII, Fig 11."""

    def emit(name, fn, args, **meta):
        fname = f"micro_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(lower_fn(fn, args))
        manifest.add("micro", name=name, file=fname, **meta)

    # GEMM sweep (Fig. 11, Table XII): M × (N, K) grid + unaligned-M variants.
    # CPU-scale shapes; the 13-offset mirrors the paper's "magic number 13".
    for n, k in [(1024, 1024), (688, 256), (256, 256)]:
        for m in [128, 256, 512, 1024]:
            for off, tag in [(0, ""), (13, "u")]:
                mm = m + off
                emit(f"gemm{tag}_m{mm}_n{n}_k{k}",
                     lambda a, b: (a @ b,),
                     [spec((mm, k)), spec((k, n))],
                     op="gemm", m=mm, n=n, k=k, flops=2 * mm * n * k)

    # Attention: naive vs flash (Table VIII), sweep sequence length.
    for s in [128, 256, 512]:
        b, h, d = 1, 8, 64
        qkv = [spec((b, h, s, d))] * 3
        emit(f"attn_naive_s{s}",
             lambda q, k, v: (ref.attention(q, k, v, causal=True),), qkv,
             op="attn_naive", b=b, h=h, s=s, d=d)
        emit(f"attn_flash_s{s}",
             lambda q, k, v: (flash_attention_fwd_impl(q, k, v, True),), qkv,
             op="attn_flash", b=b, h=h, s=s, d=d)

    # Element-wise / norm / rope operators (Table VI module shares).
    n_rows, d = 2048, 1024
    emit("rmsnorm_ref", lambda x, w: (ref.rmsnorm(x, w),),
         [spec((n_rows, d)), spec((d,))], op="rmsnorm_ref", rows=n_rows, d=d)
    emit("rmsnorm_pallas", lambda x, w: (pallas_rmsnorm(x, w),),
         [spec((n_rows, d)), spec((d,))], op="rmsnorm_pallas", rows=n_rows, d=d)
    emit("rope", lambda x: (ref.apply_rope(x, jnp.arange(512)),),
         [spec((8, 8, 512, 64))], op="rope", b=8, h=8, s=512, d=64)
    emit("silu", lambda x: (ref.silu(x),), [spec((n_rows, d))],
         op="silu", rows=n_rows, d=d)
    emit("add", lambda x, y: (x + y,), [spec((n_rows, d))] * 2,
         op="add", rows=n_rows, d=d)
    emit("softmax", lambda x: (jax.nn.softmax(x, axis=-1),),
         [spec((64, 512, 512))], op="softmax", rows=64 * 512, d=512)
    print(f"  micro ops lowered")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", default="micro,tiny",
                    help="comma-separated preset names to lower")
    ap.add_argument("--with-m100", action="store_true",
                    help="also lower the ~100M-param e2e model (large params.bin)")
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    manifest = Manifest()

    names = [n.strip() for n in args.models.split(",") if n.strip()]
    if args.with_m100 and "m100" not in names:
        names.append("m100")
    for name in names:
        cfg = M.PRESETS[name]
        print(f"[aot] lowering model '{name}'")
        write_params(cfg, out_dir, manifest)
        emit_model(cfg, out_dir, manifest)

    print("[aot] lowering microbenchmarks")
    emit_micro(out_dir, manifest)
    manifest.write(os.path.join(out_dir, "manifest.txt"))
    print(f"[aot] wrote {out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
