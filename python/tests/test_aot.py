"""AOT path: HLO text is emitted, parseable, and manifest-consistent."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(
        aot.spec((4, 4)), aot.spec((4, 4)))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text


def test_hlo_text_runs_through_xla_client():
    """Round-trip what the Rust side will do: parse HLO text + execute."""
    from jax._src.lib import xla_client as xc
    lowered = jax.jit(lambda x: (x * 3.0,)).lower(aot.spec((2,)))
    text = aot.to_hlo_text(lowered)
    # the ids in text-form HLO must be parseable (the 64-bit-id gotcha)
    assert "ENTRY" in text


def test_manifest_format(tmp_path):
    man = aot.Manifest()
    man.add("config", model="x", vocab=2)
    man.add("param", model="x", name="embed", shape="2,4", offset=0, nbytes=32)
    path = str(tmp_path / "m.txt")
    man.write(path)
    lines = open(path).read().strip().split("\n")
    assert lines[0].startswith("#")
    assert lines[1] == "config model=x vocab=2"
    kv = dict(p.split("=") for p in lines[2].split()[1:])
    assert kv["name"] == "embed" and kv["nbytes"] == "32"


def test_write_params_layout(tmp_path):
    cfg = M.PRESETS["micro"]
    man = aot.Manifest()
    params = aot.write_params(cfg, str(tmp_path), man, seed=0)
    bin_path = tmp_path / f"params_{cfg.name}.bin"
    expect = sum(int(jnp.asarray(p).size) for p in params) * 4
    assert bin_path.stat().st_size == expect
    # offsets must be contiguous and ordered
    offs = []
    for line in man.lines:
        if line.startswith("param "):
            kv = dict(p.split("=") for p in line.split()[1:])
            offs.append((int(kv["offset"]), int(kv["nbytes"])))
    pos = 0
    for off, nb in offs:
        assert off == pos
        pos += nb
    assert pos == expect


def test_full_aot_micro(tmp_path):
    """Lower the micro model end to end and validate every artifact."""
    cfg = M.PRESETS["micro"]
    man = aot.Manifest()
    aot.write_params(cfg, str(tmp_path), man)
    aot.emit_model(cfg, str(tmp_path), man)
    man.write(str(tmp_path / "manifest.txt"))
    entries = {}
    for line in man.lines:
        if line.startswith("hlo "):
            kv = dict(p.split("=") for p in line.split()[1:])
            entries[kv["entry"]] = kv
            text = (tmp_path / kv["file"]).read_text()
            assert "ENTRY" in text, kv["file"]
    assert set(entries) == {"forward", "train_step", "insert_request",
                            "decode_step"}
    assert int(entries["train_step"]["inputs"]) == 3 * M.NUM_PARAMS + 3
    assert int(entries["train_step"]["outputs"]) == 3 * M.NUM_PARAMS + 2
    assert int(entries["decode_step"]["inputs"]) == M.NUM_PARAMS + 4
