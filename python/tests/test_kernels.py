"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and block sizes) so padding/tiling edge cases in
the Pallas kernels are exercised, exactly as the benchmark-infra guide
prescribes: the kernel-vs-ref allclose is the core correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.flash_attention import (
    flash_attention,
    flash_attention_fwd_impl,
    hbm_traffic_bytes,
    vmem_bytes,
)
from compile.kernels.rmsnorm import rmsnorm as pallas_rmsnorm

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ------------------------------------------------------------ flash fwd

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    s=st.integers(1, 130),
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
)
def test_flash_matches_ref_shapes(b, h, s, d, causal):
    kq, kk, kv = keys(3, seed=b * 1000 + h * 100 + s * 10 + d)
    q, k, v = rand(kq, (b, h, s, d)), rand(kk, (b, h, s, d)), rand(kv, (b, h, s, d))
    got = flash_attention_fwd_impl(q, k, v, causal, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    block_q=st.sampled_from([8, 16, 64, 128]),
    block_k=st.sampled_from([8, 16, 64, 128]),
)
def test_flash_block_size_invariance(block_q, block_k):
    kq, kk, kv = keys(3, seed=7)
    q, k, v = (rand(kq, (2, 2, 96, 16)), rand(kk, (2, 2, 96, 16)),
               rand(kv, (2, 2, 96, 16)))
    got = flash_attention_fwd_impl(q, k, v, True, block_q=block_q, block_k=block_k)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_flash_large_scale_logits_stable():
    # online softmax must survive large score magnitudes
    kq, kk, kv = keys(3, seed=11)
    q, k, v = (rand(kq, (1, 1, 64, 8), 30.0), rand(kk, (1, 1, 64, 8), 30.0),
               rand(kv, (1, 1, 64, 8)))
    got = flash_attention_fwd_impl(q, k, v, True)
    want = ref.attention(q, k, v, causal=True)
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(got, want, atol=5e-4, rtol=1e-3)


def test_flash_causality():
    # perturbing future tokens must not change earlier outputs
    kq, kk, kv = keys(3, seed=3)
    q, k, v = rand(kq, (1, 2, 32, 8)), rand(kk, (1, 2, 32, 8)), rand(kv, (1, 2, 32, 8))
    base = flash_attention_fwd_impl(q, k, v, True)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    pert = flash_attention_fwd_impl(q, k2, v2, True)
    np.testing.assert_allclose(base[:, :, :20, :], pert[:, :, :20, :],
                               atol=1e-6, rtol=1e-6)


# ------------------------------------------------------------ flash bwd

@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 33, 64]), d=st.sampled_from([8, 16]))
def test_flash_grad_matches_ref(s, d):
    kq, kk, kv = keys(3, seed=s + d)
    q, k, v = rand(kq, (1, 2, s, d)), rand(kk, (1, 2, s, d)), rand(kv, (1, 2, s, d))

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, True)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(q, k, v, causal=True)))

    g_flash = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_flash, g_ref):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5)


# ------------------------------------------------------------ rmsnorm

@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(1, 200),
    d=st.sampled_from([8, 32, 128]),
    block=st.sampled_from([16, 64]),
)
def test_rmsnorm_matches_ref(rows, d, block):
    kx, kw = keys(2, seed=rows * 7 + d)
    x = rand(kx, (rows, d))
    w = rand(kw, (d,)) + 1.0
    got = pallas_rmsnorm(x, w, block_rows=block)
    want = ref.rmsnorm(x, w)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_rmsnorm_3d_batch():
    kx, kw = keys(2, seed=5)
    x = rand(kx, (3, 17, 64))
    w = rand(kw, (64,)) + 1.0
    np.testing.assert_allclose(pallas_rmsnorm(x, w), ref.rmsnorm(x, w),
                               atol=1e-5, rtol=1e-5)


def test_rmsnorm_scale_invariance_property():
    # rmsnorm(c*x) == rmsnorm(x) for c > 0 — the defining invariant
    kx, kw = keys(2, seed=9)
    x = rand(kx, (8, 32))
    w = jnp.ones((32,))
    np.testing.assert_allclose(pallas_rmsnorm(3.7 * x, w), pallas_rmsnorm(x, w),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ rope

def test_rope_preserves_norm():
    # rotation: per-pair L2 norm is invariant
    (kx,) = keys(1, seed=13)
    x = rand(kx, (2, 4, 16, 32))
    pos = jnp.arange(16)
    y = ref.apply_rope(x, pos)
    nx = jnp.sqrt(x[..., :16] ** 2 + x[..., 16:] ** 2)
    ny = jnp.sqrt(y[..., :16] ** 2 + y[..., 16:] ** 2)
    np.testing.assert_allclose(nx, ny, atol=1e-5, rtol=1e-5)


def test_rope_relative_property():
    # <rope(q,m), rope(k,n)> depends only on m-n: shift both by a constant
    (kx,) = keys(1, seed=17)
    q = rand(kx, (1, 1, 1, 8))
    k = rand(keys(1, seed=18)[0], (1, 1, 1, 8))
    def dot_at(m, n):
        qm = ref.apply_rope(q, jnp.array([m]))
        kn = ref.apply_rope(k, jnp.array([n]))
        return float(jnp.sum(qm * kn))
    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


def test_rope_position_zero_identity():
    (kx,) = keys(1, seed=19)
    x = rand(kx, (1, 1, 1, 16))
    y = ref.apply_rope(x, jnp.array([0]))
    np.testing.assert_allclose(x, y, atol=1e-6)


# ------------------------------------------------------------ io model

def test_vmem_model_monotone_in_blocks():
    assert vmem_bytes(64, 64, 64) < vmem_bytes(128, 64, 64) < vmem_bytes(128, 128, 64)


def test_hbm_traffic_flash_beats_naive():
    s, d = 4096, 128
    naive = 4 * (3 * s * d + 2 * s * s)  # q,k,v + S×S scores read/write
    flash = hbm_traffic_bytes(s, d, block_q=128)
    assert flash < naive


def test_xent_matches_manual():
    logits = jnp.array([[[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]]])
    targets = jnp.array([[0, 2]], dtype=jnp.int32)
    got = ref.softmax_xent(logits, targets)
    p0 = np.exp(2.0) / (np.exp(2.0) + 1.0 + np.exp(-1.0))
    want = (-np.log(p0) - np.log(1.0 / 3.0)) / 2.0
    np.testing.assert_allclose(got, want, rtol=1e-6)
