"""L2 model correctness: shapes, training dynamics, prefill/decode vs forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["micro"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    return jax.random.randint(jax.random.PRNGKey(1), (CFG.train_batch, CFG.seq),
                              0, CFG.vocab, jnp.int32)


def test_param_shapes_and_count(params):
    shapes = CFG.param_shapes()
    assert len(params) == M.NUM_PARAMS
    for name, p in zip(M.PARAM_NAMES, params):
        assert p.shape == shapes[name], name
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == CFG.param_count()


def test_forward_shape_and_finite(params, tokens):
    logits = M.forward(CFG, params, tokens)
    assert logits.shape == (CFG.train_batch, CFG.seq, CFG.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_forward_flash_matches_ref_attention(params, tokens):
    """The Pallas flash path and the naive path must agree end-to-end."""
    import dataclasses
    cfg_ref = dataclasses.replace(CFG, use_flash=False)
    a = M.forward(CFG, params, tokens)
    b = M.forward(cfg_ref, params, tokens)
    np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_initial_loss_near_uniform(params, tokens):
    loss = M.loss_fn(CFG, params, tokens)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_train_step_decreases_loss(params):
    # overfit a single repeated batch: loss must drop monotonically-ish
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, CFG.seq),
                                0, CFG.vocab, jnp.int32)
    m, v, step = M.init_opt_state(params)
    p = [jnp.array(x) for x in params]
    step_fn = jax.jit(lambda p, m, v, s, t: M.train_step(CFG, p, m, v, s, 1e-3, t))
    losses = []
    for _ in range(8):
        p, m, v, step, loss = step_fn(p, m, v, step, tokens)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_train_step_updates_all_params(params, tokens):
    m, v, step = M.init_opt_state(params)
    new_p, _, _, new_step, loss = M.train_step(CFG, params, m, v, step, 1e-3, tokens)
    assert float(new_step) == 1.0
    assert np.isfinite(float(loss))
    for name, old, new in zip(M.PARAM_NAMES, params, new_p):
        assert not np.allclose(old, new), f"{name} did not update"


def test_prefill_decode_matches_forward(params):
    """Teacher-forced decode over the cache must reproduce forward logits."""
    b = CFG.dec_batch
    p_len = CFG.prompt_len
    total = p_len + 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, total), 0, CFG.vocab,
                              jnp.int32)
    # reference: full forward over the first `total` tokens
    full = M.forward(CFG, params, toks)

    kc, vc = M.init_cache(CFG)
    last_logits = []
    for slot in range(b):
        kc, vc, lg = M.insert_request(
            CFG, params, kc, vc, jnp.int32(slot), toks[slot, :p_len],
            jnp.int32(p_len))
        last_logits.append(lg)
    # prefill logits at position p_len-1 match forward
    np.testing.assert_allclose(
        np.stack(last_logits), np.asarray(full[:, p_len - 1, :]),
        atol=2e-3, rtol=2e-3)

    # teacher-forced decode for the remaining positions
    for t in range(4):
        pos = jnp.full((b,), p_len + t, jnp.int32)
        cur = toks[:, p_len + t]
        logits, kc, vc = M.decode_step(CFG, params, kc, vc, cur, pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, p_len + t, :]),
            atol=2e-3, rtol=2e-3)


def test_decode_slots_independent(params):
    """Writing one slot must not disturb another slot's cache."""
    kc, vc = M.init_cache(CFG)
    prompt = jnp.arange(CFG.prompt_len, dtype=jnp.int32) % CFG.vocab
    kc1, vc1, _ = M.insert_request(CFG, params, kc, vc, jnp.int32(0), prompt,
                                   jnp.int32(CFG.prompt_len))
    kc2, vc2, _ = M.insert_request(CFG, params, kc1, vc1, jnp.int32(1),
                                   prompt[::-1], jnp.int32(CFG.prompt_len))
    np.testing.assert_array_equal(np.asarray(kc2[:, 0]), np.asarray(kc1[:, 0]))
    assert not np.allclose(np.asarray(kc2[:, 1]), np.asarray(kc1[:, 1]))


def test_presets_well_formed():
    for name, cfg in M.PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert cfg.head_dim % 2 == 0, name
        assert cfg.prompt_len < cfg.max_seq, name
    assert 80e6 < M.PRESETS["m100"].param_count() < 120e6
